"""Model assembly: init, forward (train/prefill), decode_step (serving) for
every assigned architecture family.

Parameter layout: nested dicts; repeated layers are STACKED along a leading
axis and executed with ``lax.scan`` (MaxText-style), which keeps HLO size and
compile time independent of depth — essential for the 88-layer dry-runs.
Attention projections are kept 3-D (d, heads, head_dim) so head dimensions
shard naturally over the model axis.

Families:
  dense   — pre-norm GQA + SwiGLU (llama/qwen/granite/tinyllama, internvl LM)
  moe     — GQA or MLA attention + routed experts (qwen3-moe, deepseek-v2)
  ssm     — Mamba-2 stack (mamba2-1.3b)
  hybrid  — Mamba-2 + shared attention block every k layers (zamba2)
  encdec  — whisper: bidirectional encoder + causal decoder w/ cross-attn
  vlm     — dense LM whose first ``vision_patches`` positions take patch
            embeddings from the (stubbed) vision frontend
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..compat import shard_map
from jax.sharding import PartitionSpec as P

from .attention import (
    decode_attention,
    flash_attention,
    mla_decode_attention,
    mla_expand,
)
from .config import ModelConfig
from .layers import KeyGen, apply_rope, dense_init, embed_init, rms_norm, sinusoidal_positions, swiglu
from .moe import moe_ffn
from .ssm import mamba2_decode, mamba2_forward


# =============================== init =========================================
def _init_attn(kg, cfg: ModelConfig, dt):
    d, Hq, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.kv_heads, cfg.hdim
    p = {
        "wq": dense_init(kg(), (d, Hq, Dh), dt),
        "wk": dense_init(kg(), (d, Hkv, Dh), dt),
        "wv": dense_init(kg(), (d, Hkv, Dh), dt),
        "wo": dense_init(kg(), (Hq, Dh, d), dt, scale=1.0 / np.sqrt(Hq * Dh)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hq, Dh), dt)
        p["bk"] = jnp.zeros((Hkv, Dh), dt)
        p["bv"] = jnp.zeros((Hkv, Dh), dt)
    return p


def _init_mla(kg, cfg: ModelConfig, dt):
    d, H = cfg.d_model, cfg.num_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "w_q": dense_init(kg(), (d, H, dn + dr), dt),
        "w_dkv": dense_init(kg(), (d, r + dr), dt),
        "w_uk": dense_init(kg(), (r, H, dn), dt),
        "w_uv": dense_init(kg(), (r, H, dv), dt),
        "wo": dense_init(kg(), (H, dv, d), dt, scale=1.0 / np.sqrt(H * dv)),
    }


def _init_mlp(kg, cfg: ModelConfig, dt, ff=None):
    d = cfg.d_model
    ff = ff or cfg.d_ff
    return {
        "w_gate": dense_init(kg(), (d, ff), dt),
        "w_up": dense_init(kg(), (d, ff), dt),
        "w_down": dense_init(kg(), (ff, d), dt, scale=1.0 / np.sqrt(ff)),
    }


def _init_moe(kg, cfg: ModelConfig, dt):
    d, E, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    p = {
        "router": dense_init(kg(), (d, E), jnp.float32),
        "experts": {
            "w_gate": dense_init(kg(), (E, d, f), dt),
            "w_up": dense_init(kg(), (E, d, f), dt),
            "w_down": dense_init(kg(), (E, f, d), dt, scale=1.0 / np.sqrt(f)),
        },
    }
    if cfg.num_shared_experts:
        fs = cfg.moe_d_ff * cfg.num_shared_experts
        p["shared"] = {
            "w_gate": dense_init(kg(), (d, fs), dt),
            "w_up": dense_init(kg(), (d, fs), dt),
            "w_down": dense_init(kg(), (fs, d), dt, scale=1.0 / np.sqrt(fs)),
        }
    return p


def _init_mamba(kg, cfg: ModelConfig, dt):
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * N
    return {
        "in_proj": dense_init(kg(), (d, 2 * di + 2 * N + H), dt),
        "conv_w": dense_init(kg(), (cfg.ssm_conv, conv_ch), dt, scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "d_skip": jnp.ones((H,), jnp.float32),
        "norm": jnp.ones((di,), dt),
        "out_proj": dense_init(kg(), (di, d), dt),
    }


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    kg = KeyGen(key)
    dt = cfg.jdtype
    d = cfg.d_model
    params: Dict[str, Any] = {
        "embed": embed_init(kg(), (cfg.vocab_size, d), dt),
        "final_norm": jnp.ones((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kg(), (d, cfg.vocab_size), dt)

    def dense_block():
        return {
            "ln1": jnp.ones((d,), dt), "ln2": jnp.ones((d,), dt),
            "attn": _init_attn(kg, cfg, dt), "mlp": _init_mlp(kg, cfg, dt),
        }

    if cfg.family in ("dense", "vlm"):
        params["blocks"] = _stack([dense_block() for _ in range(cfg.num_layers)])
    elif cfg.family == "moe":
        # Uniform stacked blocks so a single lax.scan covers mixed layers:
        # every layer carries MoE params; when first_dense_layers > 0 every
        # layer also carries a dense MLP and `is_dense` selects per layer
        # (the dense dup costs one small MLP per MoE layer — dwarfed by the
        # expert stack — and keeps the scan pytree uniform).
        nd = cfg.first_dense_layers
        blocks = []
        for li in range(cfg.num_layers):
            b = {
                "ln1": jnp.ones((d,), dt), "ln2": jnp.ones((d,), dt),
                "attn": _init_mla(kg, cfg, dt) if cfg.mla else _init_attn(kg, cfg, dt),
                "moe": _init_moe(kg, cfg, dt),
            }
            if nd:
                b["mlp"] = _init_mlp(kg, cfg, dt, ff=cfg.dense_d_ff or cfg.d_ff)
            blocks.append(b)
        params["blocks"] = _stack(blocks)
    elif cfg.family == "ssm":
        params["blocks"] = _stack([
            {"ln": jnp.ones((d,), dt), "mamba": _init_mamba(kg, cfg, dt)}
            for _ in range(cfg.num_layers)
        ])
    elif cfg.family == "hybrid":
        params["blocks"] = _stack([
            {"ln": jnp.ones((d,), dt), "mamba": _init_mamba(kg, cfg, dt)}
            for _ in range(cfg.num_layers)
        ])
        params["shared_block"] = dense_block()
    elif cfg.family == "encdec":
        params["enc_blocks"] = _stack([dense_block() for _ in range(cfg.enc_layers)])
        dec = []
        for _ in range(cfg.num_layers):
            b = dense_block()
            b["ln_x"] = jnp.ones((d,), dt)
            b["xattn"] = _init_attn(kg, cfg, dt)
            dec.append(b)
        params["blocks"] = _stack(dec)
        params["enc_norm"] = jnp.ones((d,), dt)
    else:
        raise ValueError(cfg.family)
    return params


# =============================== forward ======================================
def _attn_sublayer(blk, h, cfg: ModelConfig, *, causal: bool, pos_offset: int = 0,
                   use_rope: bool = True, kv_override=None, mesh=None):
    """Standard GQA attention over a full sequence.

    When the head count does not divide the model axis (qwen2.5's 40 heads
    on a 16-way axis), the partitioner would REPLICATE the whole attention
    computation over `model` (16x redundant flops + a full-size score
    buffer).  Fallback: sequence-parallel attention — shard q's sequence dim
    over `model` (KV replicated there), compute 1/16 of the rows per shard,
    then return to the batch-sharded layout for the residual add."""
    B, S, d = h.shape
    a = blk["attn"]
    x = rms_norm(h, blk["ln1"], cfg.rms_eps)
    seq_par = (mesh is not None and "model" in mesh.axis_names
               and cfg.num_heads % mesh.shape["model"] != 0
               and S % mesh.shape["model"] == 0)
    if seq_par:
        bs = _bspec(mesh, B)
        x = _constrain(x, mesh, P(bs, "model", None))
    q = jnp.einsum("bsd,dhk->bshk", x, a["wq"])
    kv_src = kv_override if kv_override is not None else x
    k = jnp.einsum("bsd,dhk->bshk", kv_src, a["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_src, a["wv"])
    if cfg.qkv_bias:
        q, k, v = q + a["bq"], k + a["bk"], v + a["bv"]
    if use_rope:
        qpos = pos_offset + jnp.arange(S)
        kpos = jnp.arange(k.shape[1])
        q = apply_rope(q, qpos, cfg.rope_theta)
        k = apply_rope(k, kpos, cfg.rope_theta)
    if seq_par:
        bs = _bspec(mesh, B)
        q = _constrain(q, mesh, P(bs, "model", None, None))
        k = _constrain(k, mesh, P(bs, None, None, None))  # replicated on model
        v = _constrain(v, mesh, P(bs, None, None, None))
    o = flash_attention(q, k, v, causal=causal, q_offset=pos_offset)
    out = jnp.einsum("bshk,hkd->bsd", o, a["wo"])
    if seq_par:
        out = _constrain(out, mesh, P(_bspec(mesh, B), None, None))
    return h + out


def _mla_sublayer(blk, h, cfg: ModelConfig):
    B, S, d = h.shape
    a = blk["attn"]
    x = rms_norm(h, blk["ln1"], cfg.rms_eps)
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dhk->bshk", x, a["w_q"])          # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    ckv_kr = x @ a["w_dkv"]                                # (B,S,r+dr)
    c_kv, k_rope = ckv_kr[..., :cfg.kv_lora_rank], ckv_kr[..., cfg.kv_lora_rank:]
    pos = jnp.arange(S)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], pos, cfg.rope_theta)  # (B,S,1,dr)
    k_nope, v = mla_expand(a, c_kv, cfg)                  # (B,S,H,dn),(B,S,H,dv)
    H = cfg.num_heads
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = flash_attention(q_full, k, v, causal=True, scale=(dn + dr) ** -0.5)
    return h + jnp.einsum("bshk,hkd->bsd", o, a["wo"]), (c_kv, k_rope[:, :, 0, :])


def _mlp_sublayer(blk, h, cfg: ModelConfig, key="mlp", ln="ln2"):
    x = rms_norm(h, blk[ln], cfg.rms_eps)
    m = blk[key]
    return h + swiglu(x, m["w_gate"], m["w_up"], m["w_down"])


def _moe_sublayer(blk, h, cfg: ModelConfig, mesh):
    x = rms_norm(h, blk["ln2"], cfg.rms_eps)
    if mesh is not None and mesh.shape.get("model", 1) > 1:
        batch_axes = tuple(a for a in mesh.axis_names if a != "model")
        fn = functools.partial(
            moe_ffn, cfg=cfg, axis="model", axis_size=mesh.shape["model"])
        param_specs = {
            "router": P(None, None),
            "experts": {
                "w_gate": P("model", None, None),
                "w_up": P("model", None, None),
                "w_down": P("model", None, None),
            },
        }
        if cfg.num_shared_experts:
            param_specs["shared"] = {
                "w_gate": P(None, None), "w_up": P(None, None),
                "w_down": P(None, None),
            }
        out = shard_map(
            fn, mesh=mesh,
            in_specs=(param_specs, P(batch_axes, None, None)),
            out_specs=P(batch_axes, None, None),
            check_vma=False,
        )(blk["moe"], x)
    else:
        out = moe_ffn(blk["moe"], x, cfg)
    return h + out


def _shared_attn_block(shared, h, cfg: ModelConfig):
    h = _attn_sublayer(shared, h, cfg, causal=True)
    h = _mlp_sublayer(shared, h, cfg)
    return h


def _maybe_ckpt(fn, remat: bool):
    return jax.checkpoint(fn) if remat else fn


def _bspec(mesh, batch: int):
    """Batch-axis names if they divide the batch, else None."""
    if mesh is None:
        return None
    ba = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    if not ba:
        return None
    import numpy as _np
    nb = int(_np.prod([mesh.shape[a] for a in ba]))
    return ba if batch % nb == 0 else None


def _constrain(x, mesh, spec: P):
    """Activation sharding constraint — without these the partitioner is free
    to replicate the batch dim whenever an FSDP-sharded weight contraction
    competes for the data axis (it does, and it costs ~5x memory)."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding
    return lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def forward(
    params: Dict,
    cfg: ModelConfig,
    tokens: jax.Array,                  # (B, S) int32 (decoder tokens)
    *,
    patches: Optional[jax.Array] = None,      # vlm: (B, n_patch, d)
    enc_inputs: Optional[jax.Array] = None,   # encdec: (B, S_enc, d) frame embeds
    mesh=None,
    remat: bool = False,
) -> jax.Array:
    """Full-sequence forward; returns logits (B, S, vocab)."""
    dt = cfg.jdtype
    bs = _bspec(mesh, tokens.shape[0])
    act_spec = P(bs, None, None)
    h = params["embed"][tokens]
    h = _constrain(h, mesh, act_spec)
    if cfg.family == "vlm" and patches is not None:
        npatch = patches.shape[1]
        h = jnp.concatenate([patches.astype(h.dtype), h[:, npatch:]], axis=1)
    if cfg.encdec:
        h = h + sinusoidal_positions(h.shape[1], cfg.d_model).astype(h.dtype)
    _c = lambda x: _constrain(x, mesh, act_spec)

    if cfg.family in ("dense", "vlm"):
        def body(carry, blk):
            x = _attn_sublayer(blk, carry, cfg, causal=True, mesh=mesh)
            x = _mlp_sublayer(blk, x, cfg)
            return _c(x), None
        h, _ = lax.scan(_maybe_ckpt(body, remat), h, params["blocks"])

    elif cfg.family == "moe":
        has_dense = bool(cfg.first_dense_layers)

        def moe_body(carry, xs):
            blk, is_dense = xs
            if cfg.mla:
                x, _ = _mla_sublayer(blk, carry, cfg)
            else:
                x = _attn_sublayer(blk, carry, cfg, causal=True, mesh=mesh)
            if has_dense:
                x = lax.cond(
                    is_dense > 0,
                    lambda hh: _mlp_sublayer(blk, hh, cfg),
                    lambda hh: _moe_sublayer(blk, hh, cfg, mesh),
                    x,
                )
            else:
                x = _moe_sublayer(blk, x, cfg, mesh)
            return _c(x), None
        is_dense = (jnp.arange(cfg.num_layers) < cfg.first_dense_layers).astype(jnp.int32)
        h, _ = lax.scan(_maybe_ckpt(moe_body, remat), h,
                        (params["blocks"], is_dense))

    elif cfg.family == "ssm":
        def body(carry, blk):
            x = rms_norm(carry, blk["ln"], cfg.rms_eps)
            y, _ = mamba2_forward(blk["mamba"], x, cfg)
            return _c(carry + y), None
        h, _ = lax.scan(_maybe_ckpt(body, remat), h, params["blocks"])

    elif cfg.family == "hybrid":
        shared = params["shared_block"]
        every = cfg.shared_attn_every

        def body(carry, xs):
            idx, blk = xs
            h_in = carry
            x = rms_norm(h_in, blk["ln"], cfg.rms_eps)
            y, _ = mamba2_forward(blk["mamba"], x, cfg)
            h_out = h_in + y
            h_out = lax.cond(
                (idx % every) == (every - 1),
                lambda hh: _shared_attn_block(shared, hh, cfg),
                lambda hh: hh,
                h_out,
            )
            return _c(h_out), None
        idxs = jnp.arange(cfg.num_layers)
        h, _ = lax.scan(_maybe_ckpt(body, remat), h, (idxs, params["blocks"]))

    elif cfg.family == "encdec":
        enc = enc_inputs.astype(dt)
        enc = enc + sinusoidal_positions(enc.shape[1], cfg.d_model).astype(dt)

        def enc_body(carry, blk):
            x = _attn_sublayer(blk, carry, cfg, causal=False, use_rope=False,
                               mesh=mesh)
            x = _mlp_sublayer(blk, x, cfg)
            return _c(x), None
        enc, _ = lax.scan(_maybe_ckpt(enc_body, remat), enc, params["enc_blocks"])
        enc = rms_norm(enc, params["enc_norm"], cfg.rms_eps)

        def dec_body(carry, blk):
            x = _attn_sublayer(blk, carry, cfg, causal=True, use_rope=False,
                               mesh=mesh)
            # cross-attention (queries from x, kv from encoder output)
            a = blk["xattn"]
            xx = rms_norm(x, blk["ln_x"], cfg.rms_eps)
            q = jnp.einsum("bsd,dhk->bshk", xx, a["wq"])
            k = jnp.einsum("bsd,dhk->bshk", enc, a["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc, a["wv"])
            o = flash_attention(q, k, v, causal=False)
            x = x + jnp.einsum("bshk,hkd->bsd", o, a["wo"])
            x = _mlp_sublayer(blk, x, cfg)
            return _c(x), None
        h, _ = lax.scan(_maybe_ckpt(dec_body, remat), h, params["blocks"])
    else:
        raise ValueError(cfg.family)

    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    vshard = ("model" if mesh is not None and "model" in mesh.axis_names
              and cfg.vocab_size % mesh.shape["model"] == 0 else None)
    return _constrain(logits, mesh, P(bs, None, vshard))


def loss_fn(
    params: Dict,
    cfg: ModelConfig,
    tokens: jax.Array,
    labels: jax.Array,
    *,
    patches=None,
    enc_inputs=None,
    mesh=None,
    remat: bool = True,
) -> jax.Array:
    logits = forward(params, cfg, tokens, patches=patches, enc_inputs=enc_inputs,
                     mesh=mesh, remat=remat)
    logits = logits.astype(jnp.float32)
    # Partitioner-friendly NLL: the vocab dim is sharded over `model`, and a
    # take_along_axis gather there would all-gather the full (B,S,V) logits.
    # logsumexp + masked-sum both reduce over the sharded dim (lowered to
    # per-shard partials + psum), so nothing is ever gathered.
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    picked = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1)
    nll = lse - picked
    zloss = 1e-4 * jnp.square(lse)  # PaLM-style stabiliser
    return jnp.mean(nll + zloss)


# =============================== decode =======================================
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0, dtype=None) -> Dict:
    """Allocate the serving cache for one model."""
    dt = dtype or cfg.jdtype
    L, Hkv, Dh = cfg.num_layers, cfg.kv_heads, cfg.hdim
    cache: Dict[str, Any] = {"len": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "vlm"):
        cache["k"] = jnp.zeros((L, batch, max_len, Hkv, Dh), dt)
        cache["v"] = jnp.zeros((L, batch, max_len, Hkv, Dh), dt)
    elif cfg.family == "moe":
        nm = cfg.num_layers - cfg.first_dense_layers
        if cfg.mla:
            cache["ckv"] = jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), dt)
            cache["kr"] = jnp.zeros((L, batch, max_len, cfg.qk_rope_dim), dt)
        else:
            cache["k"] = jnp.zeros((L, batch, max_len, Hkv, Dh), dt)
            cache["v"] = jnp.zeros((L, batch, max_len, Hkv, Dh), dt)
    elif cfg.family == "ssm":
        cache["ssm"] = jnp.zeros(
            (L, batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32)
        cache["conv"] = jnp.zeros(
            (L, batch, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state), dt)
    elif cfg.family == "hybrid":
        cache["ssm"] = jnp.zeros(
            (L, batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state), jnp.float32)
        cache["conv"] = jnp.zeros(
            (L, batch, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state), dt)
        sites = cfg.num_layers // cfg.shared_attn_every
        cache["sk"] = jnp.zeros((sites, batch, max_len, Hkv, Dh), dt)
        cache["sv"] = jnp.zeros((sites, batch, max_len, Hkv, Dh), dt)
    elif cfg.family == "encdec":
        cache["k"] = jnp.zeros((L, batch, max_len, Hkv, Dh), dt)
        cache["v"] = jnp.zeros((L, batch, max_len, Hkv, Dh), dt)
        cache["enc_k"] = jnp.zeros((L, batch, enc_len, Hkv, Dh), dt)
        cache["enc_v"] = jnp.zeros((L, batch, enc_len, Hkv, Dh), dt)
    return cache


def _decode_attn(blk, h, cfg, k_cache, v_cache, cur_len, use_rope=True):
    """One-token attention; returns (h', new_k_cache, new_v_cache)."""
    B = h.shape[0]
    a = blk["attn"]
    x = rms_norm(h, blk["ln1"], cfg.rms_eps)
    q = jnp.einsum("bsd,dhk->bshk", x, a["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, a["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, a["wv"])
    if cfg.qkv_bias:
        q, k, v = q + a["bq"], k + a["bk"], v + a["bv"]
    if use_rope:
        posv = jnp.full((1,), 1, jnp.int32) * cur_len
        q = apply_rope(q, posv, cfg.rope_theta)
        k = apply_rope(k, posv, cfg.rope_theta)
    k_cache = lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                       (0, cur_len, 0, 0))
    v_cache = lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                       (0, cur_len, 0, 0))
    o = decode_attention(q, k_cache, v_cache, cur_len + 1)
    return h + jnp.einsum("bshk,hkd->bsd", o, a["wo"]), k_cache, v_cache


def decode_step(
    params: Dict,
    cfg: ModelConfig,
    cache: Dict,
    tokens: jax.Array,        # (B,) int32 — the new token per sequence
    *,
    mesh=None,
) -> Tuple[jax.Array, Dict]:
    """One serving step: consume one token, return logits and updated cache."""
    B = tokens.shape[0]
    cur = cache["len"]
    h = params["embed"][tokens][:, None, :]           # (B,1,d)
    if cfg.encdec:
        # positions are handled by sinusoidal add at embed time in forward;
        # decode uses the position slice at cur.
        pe = sinusoidal_positions(cache["k"].shape[2], cfg.d_model)
        h = h + lax.dynamic_slice(pe, (cur, 0), (1, cfg.d_model))[None].astype(h.dtype)

    new_cache = dict(cache)

    if cfg.family in ("dense", "vlm"):
        def body(carry, xs):
            hh = carry
            blk, kc, vc = xs
            hh, kc, vc = _decode_attn(blk, hh, cfg, kc, vc, cur)
            hh = _mlp_sublayer(blk, hh, cfg)
            return hh, (kc, vc)
        h, (ks, vs) = lax.scan(body, h, (params["blocks"], cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = ks, vs

    elif cfg.family == "moe":
        has_dense = bool(cfg.first_dense_layers)
        dense_mask = (jnp.arange(cfg.num_layers) < cfg.first_dense_layers).astype(jnp.int32)

        def ffn_select(blk, is_dense, hh):
            if has_dense:
                return lax.cond(
                    is_dense > 0,
                    lambda x_: _mlp_sublayer(blk, x_, cfg),
                    lambda x_: _moe_sublayer(blk, x_, cfg, mesh),
                    hh,
                )
            return _moe_sublayer(blk, hh, cfg, mesh)

        if cfg.mla:
            def body(carry, xs):
                hh = carry
                blk, is_dense, ckv_c, kr_c = xs
                a = blk["attn"]
                x = rms_norm(hh, blk["ln1"], cfg.rms_eps)
                dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
                q = jnp.einsum("bsd,dhk->bshk", x, a["w_q"])
                q_nope, q_rope = q[..., :dn], q[..., dn:]
                ckv_kr = x @ a["w_dkv"]
                c_kv = ckv_kr[..., :cfg.kv_lora_rank]
                k_r = ckv_kr[..., cfg.kv_lora_rank:]
                posv = jnp.full((1,), 1, jnp.int32) * cur
                q_rope = apply_rope(q_rope, posv, cfg.rope_theta)
                k_r = apply_rope(k_r[:, :, None, :], posv, cfg.rope_theta)[:, :, 0, :]
                ckv_c = lax.dynamic_update_slice(
                    ckv_c, c_kv.astype(ckv_c.dtype), (0, cur, 0))
                kr_c = lax.dynamic_update_slice(
                    kr_c, k_r.astype(kr_c.dtype), (0, cur, 0))
                ctx = mla_decode_attention(a, q_nope, q_rope, ckv_c, kr_c, cur + 1, cfg)
                hh = hh + jnp.einsum("bshk,hkd->bsd", ctx, a["wo"])
                hh = ffn_select(blk, is_dense, hh)
                return hh, (ckv_c, kr_c)
            h, (cs, ks) = lax.scan(
                body, h,
                (params["blocks"], dense_mask, cache["ckv"], cache["kr"]))
            new_cache["ckv"], new_cache["kr"] = cs, ks
        else:
            def body(carry, xs):
                hh = carry
                blk, is_dense, kc, vc = xs
                hh, kc, vc = _decode_attn(blk, hh, cfg, kc, vc, cur)
                hh = ffn_select(blk, is_dense, hh)
                return hh, (kc, vc)
            h, (ks, vs) = lax.scan(
                body, h,
                (params["blocks"], dense_mask, cache["k"], cache["v"]))
            new_cache["k"], new_cache["v"] = ks, vs

    elif cfg.family == "ssm":
        def body(carry, xs):
            hh = carry
            blk, ss, cs = xs
            x = rms_norm(hh, blk["ln"], cfg.rms_eps)
            y, ss, cs = mamba2_decode(blk["mamba"], x[:, 0, :], cfg, ss, cs)
            return hh + y[:, None, :], (ss, cs)
        h, (ss, cs) = lax.scan(body, h, (params["blocks"], cache["ssm"], cache["conv"]))
        new_cache["ssm"], new_cache["conv"] = ss, cs

    elif cfg.family == "hybrid":
        shared = params["shared_block"]
        every = cfg.shared_attn_every

        def body(carry, xs):
            hh, sk, sv = carry
            idx, blk, ss, cs = xs
            x = rms_norm(hh, blk["ln"], cfg.rms_eps)
            y, ss, cs = mamba2_decode(blk["mamba"], x[:, 0, :], cfg, ss, cs)
            hh = hh + y[:, None, :]

            def apply_shared(args):
                hh_, sk_, sv_ = args
                site = idx // every
                kc = sk_[site]
                vc = sv_[site]
                hh_, kc, vc = _decode_attn(shared, hh_, cfg, kc, vc, cur)
                hh_ = _mlp_sublayer(shared, hh_, cfg)
                sk_ = lax.dynamic_update_index_in_dim(sk_, kc, site, 0)
                sv_ = lax.dynamic_update_index_in_dim(sv_, vc, site, 0)
                return hh_, sk_, sv_

            hh, sk, sv = lax.cond(
                (idx % every) == (every - 1), apply_shared, lambda a: a, (hh, sk, sv))
            return (hh, sk, sv), (ss, cs)

        idxs = jnp.arange(cfg.num_layers)
        (h, sk, sv), (ss, cs) = lax.scan(
            body, (h, cache["sk"], cache["sv"]),
            (idxs, params["blocks"], cache["ssm"], cache["conv"]))
        new_cache.update(ssm=ss, conv=cs, sk=sk, sv=sv)

    elif cfg.family == "encdec":
        def body(carry, xs):
            hh = carry
            blk, kc, vc, ekc, evc = xs
            hh, kc, vc = _decode_attn(blk, hh, cfg, kc, vc, cur, use_rope=False)
            a = blk["xattn"]
            xx = rms_norm(hh, blk["ln_x"], cfg.rms_eps)
            q = jnp.einsum("bsd,dhk->bshk", xx, a["wq"])
            enc_len = ekc.shape[1]
            o = decode_attention(q, ekc, evc, jnp.asarray(enc_len, jnp.int32))
            hh = hh + jnp.einsum("bshk,hkd->bsd", o, a["wo"])
            hh = _mlp_sublayer(blk, hh, cfg)
            return hh, (kc, vc)
        h, (ks, vs) = lax.scan(
            body, h,
            (params["blocks"], cache["k"], cache["v"], cache["enc_k"], cache["enc_v"]))
        new_cache["k"], new_cache["v"] = ks, vs
    else:
        raise ValueError(cfg.family)

    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)[:, 0, :]
    new_cache["len"] = cur + 1
    return logits, new_cache
