"""Model configuration covering every assigned architecture family."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    vocab_size: int
    num_kv_heads: Optional[int] = None
    head_dim: Optional[int] = None
    qkv_bias: bool = False

    # MoE
    moe: bool = False
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    first_dense_layers: int = 0      # deepseek: layer 0 is a dense MLP
    dense_d_ff: int = 0              # ... with this hidden size
    norm_topk: bool = True
    capacity_factor: float = 1.25

    # MLA (deepseek)
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2 / zamba2)
    ssm: bool = False
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    shared_attn_every: int = 0       # zamba2: shared attn+mlp block cadence

    # encoder-decoder (whisper)
    encdec: bool = False
    enc_layers: int = 0

    # vlm
    vision_patches: int = 0          # internvl: leading patch-embedding slots

    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    # -- derived --------------------------------------------------------------
    @property
    def kv_heads(self) -> int:
        return self.num_kv_heads or self.num_heads

    @property
    def hdim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size  # lm head
        def attn_params():
            if self.mla:
                a = d * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)  # W_q
                a += d * (self.kv_lora_rank + self.qk_rope_dim)                 # W_dkv+rope
                a += self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                a += self.num_heads * self.v_head_dim * d                        # W_o
                return a
            a = d * self.num_heads * self.hdim          # q
            a += 2 * d * self.kv_heads * self.hdim      # k, v
            a += self.num_heads * self.hdim * d         # o
            if self.qkv_bias:
                a += (self.num_heads + 2 * self.kv_heads) * self.hdim
            return a
        def mlp_params(ff):
            return 3 * d * ff
        def moe_params():
            m = d * self.num_experts  # router
            m += self.num_experts * mlp_params(self.moe_d_ff) // 1
            m += self.num_shared_experts * mlp_params(self.moe_d_ff)
            return m
        def ssm_params():
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            p = d * (2 * di + 2 * ns + nh)   # in_proj (x, z, B, C, dt)
            p += self.ssm_conv * (di + 2 * ns)
            p += nh * 3                       # A, D, dt_bias
            p += di * d                       # out_proj
            return p
        per_layer = 0
        if self.family == "ssm":
            per_layer = ssm_params()
        elif self.family == "hybrid":
            per_layer = ssm_params()
            n += attn_params() + mlp_params(self.d_ff)  # one shared block
        elif self.moe:
            dense = self.first_dense_layers
            n += dense * (attn_params() + mlp_params(self.dense_d_ff or self.d_ff))
            per_layer = attn_params() + moe_params()
            L = L - dense
        else:
            per_layer = attn_params() + mlp_params(self.d_ff)
        n += L * per_layer
        if self.encdec:
            # encoder layers: self-attn + mlp; decoder counted above, add cross.
            n += self.enc_layers * (attn_params() + mlp_params(self.d_ff))
            n += self.num_layers * attn_params()  # cross-attention
        n += 2 * self.num_layers * d  # norms (approx; + final)
        return int(n)

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: only routed-in experts)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        unused = (self.num_experts - self.experts_per_token) * 3 * self.d_model * self.moe_d_ff
        return int(full - (self.num_layers - self.first_dense_layers) * unused)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic path; see DESIGN.md).
LONG_CONTEXT_OK = {"mamba2-1.3b", "zamba2-1.2b"}
