"""LM-family model substrate: composable JAX transformer/SSM stack covering
the ten assigned architectures (dense / GQA / MLA / MoE / SSM / hybrid /
enc-dec / VLM-backbone), with train_step and serve_step entry points."""
from .config import ModelConfig
from .transformer import init_params, forward, decode_step, loss_fn

__all__ = ["ModelConfig", "init_params", "forward", "decode_step", "loss_fn"]
