"""Out-of-core LM serving: layer-weight streaming (the paper's Algorithm 1
applied to transformer weights).

Mapping from the stencil setting (DESIGN.md §4):
  loop chain      -> the layer stack (executed in order, every step)
  dataset         -> one layer's weight slice
  fast memory     -> device HBM;  slow memory -> host DRAM (pinned_host)
  3 slots         -> device-resident rings of ``window`` layer slices
  read-only opt   -> weights NEVER download (they are read-only)
  write-first opt -> activations/caches never upload (born on device)
  prefetch        -> layer l+1's weights upload while layer l computes; and
                     the next *step*'s layer-0 weights upload during the last
                     layer of this step (the paper's cross-chain speculative
                     prefetch — here the next chain provably looks the same,
                     so it always hits)

JAX's async dispatch provides the overlap: ``device_put`` of slice l+1 is
issued before layer l's compute is consumed, so the copy runs behind the
matmuls exactly like stream 1 behind stream 0.  The ledger models the link
occupancy to report the achievable overlap on the TPU constants.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.memory import HardwareModel, TPU_V5E, TransferLedger
from .config import ModelConfig
from .transformer import decode_step, init_cache


def _layer_bytes(host_blocks, li: int) -> int:
    return int(sum(np.asarray(l[li]).nbytes for l in jax.tree.leaves(host_blocks)))


def _slice_layer(host_blocks, li: int):
    return jax.tree.map(lambda l: jnp.asarray(l[li]), host_blocks)


@dataclass
class StreamStats:
    uploaded_bytes: int = 0
    steps: int = 0
    modelled_step_s: float = 0.0
    compute_bound_fraction: float = 0.0


class LayerStreamer:
    """Runs decode steps for a model whose layer weights live in host memory.

    ``params`` must be the standard tree; stacked ``blocks`` leaves are kept
    as host numpy (slow memory).  Non-layer params (embeddings, norms, head)
    stay device-resident — they are used every step (the paper keeps
    frequently-reused data in fast memory, cf. Heinecke et al. in §2).
    """

    def __init__(self, params: Dict, cfg: ModelConfig, *, window: int = 3,
                 hw: HardwareModel = TPU_V5E,
                 flops_per_layer_per_token: Optional[float] = None):
        self.cfg = cfg
        self.window = max(2, window)
        self.hw = hw
        self.host_blocks = jax.tree.map(np.asarray, params["blocks"])
        self.resident = {k: v for k, v in params.items() if k != "blocks"}
        self.L = cfg.num_layers
        self._ring: Dict[int, Any] = {}
        self.ledger = TransferLedger(hw)
        self.stats = StreamStats()
        self._flops_per_layer_token = flops_per_layer_per_token or (
            2.0 * cfg.active_param_count() / max(cfg.num_layers, 1))

    # -- slot management ---------------------------------------------------------
    def _fetch(self, li: int):
        if li in self._ring:
            return self._ring[li]
        sl = _slice_layer(self.host_blocks, li)
        self._ring[li] = sl
        self.stats.uploaded_bytes += _layer_bytes(self.host_blocks, li)
        while len(self._ring) > self.window:
            # evict the slice furthest BEHIND the current layer in ring
            # order (so a speculatively-prefetched layer 0 survives the
            # tail of the previous step); read-only => discard, never
            # download (§4.1).
            stalest = max((k for k in self._ring if k != li),
                          key=lambda k: (li - k) % self.L)
            del self._ring[stalest]
        return sl

class StreamedDecoder(LayerStreamer):
    """Streamed decode for the dense/vlm families (llama-style blocks).

    At most ``window`` layer slices are device-resident at any point; slice
    l+1's host->device copy is ISSUED before layer l's compute is consumed
    (JAX async dispatch = stream-1-behind-stream-0 overlap).  Math is
    identical to ``decode_step`` (validated in tests/test_offload.py).
    """

    def decode(self, cache: Dict, tokens: jax.Array) -> Tuple[jax.Array, Dict]:
        from .layers import rms_norm
        from .transformer import _decode_attn, _mlp_sublayer

        cfg = self.cfg
        assert cfg.family in ("dense", "vlm"), "streamed decode: dense families"
        cur = cache["len"]
        batch = tokens.shape[0]
        h = self.resident["embed"][tokens][:, None, :]
        self._fetch(0)
        ks, vs = [], []
        for li in range(self.L):
            if li + 1 < self.L:
                self._fetch(li + 1)          # prefetch next layer (stream 1)
            blk = self._ring[li]
            h, kc, vc = _decode_attn(blk, h, cfg, cache["k"][li], cache["v"][li], cur)
            h = _mlp_sublayer(blk, h, cfg)
            ks.append(kc)
            vs.append(vc)
        # speculative prefetch for the NEXT step's first layer (§4.1): the
        # next chain is the same layer stack, so this always hits.
        self._fetch(0)
        h = rms_norm(h, self.resident["final_norm"], cfg.rms_eps)
        head = (self.resident["embed"].T if cfg.tie_embeddings
                else self.resident["lm_head"])
        logits = jnp.einsum("bsd,dv->bsv", h, head)[:, 0, :]
        new_cache = dict(cache)
        new_cache["k"] = jnp.stack(ks)
        new_cache["v"] = jnp.stack(vs)
        new_cache["len"] = cur + 1

        # ledger: model the overlapped schedule on the target hardware
        t_cmp_layer = self._flops_per_layer_token * batch / self.hw.flops
        up_eid = cmp_eid = None
        for li in range(self.L):
            nb = _layer_bytes(self.host_blocks, li)
            deps = tuple(e for e in (up_eid,) if e is not None)
            up_eid = self.ledger.add(1, "upload", nb, self.ledger.t_up(nb), deps)
            cdeps = [up_eid] + ([cmp_eid] if cmp_eid is not None else [])
            cmp_eid = self.ledger.add(0, "compute", 0, t_cmp_layer, tuple(cdeps))
        self.stats.steps += 1
        self.stats.modelled_step_s = self.ledger.simulate() / self.stats.steps
        return logits, new_cache

    def device_resident_bytes(self) -> int:
        """Max weight bytes on device at any time (the out-of-core claim)."""
        return self.window * max(
            _layer_bytes(self.host_blocks, li) for li in range(self.L))
