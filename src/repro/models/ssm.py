"""Mamba-2 (SSD — state-space duality) block: chunked matmul-form scan.

The SSD algorithm is the TPU-friendly formulation of the selective scan: the
sequence is cut into chunks of Q tokens; within a chunk attention-like
(Q x Q) semiseparable matmuls run on the MXU, and an (state x headdim) chunk
state is relayed across chunks by a short ``lax.scan`` — structurally the
same "tile + carried edge" pattern as the paper's skewed tiling, one reason
this arch pairs naturally with the repo (DESIGN.md §4).

Decode is the O(1) recurrent update on the (heads, headdim, state) state.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _depthwise_causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B, S, C); w: (K, C) depthwise causal conv."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):  # K = 4: unrolled taps fuse into one VPU pass
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[K - 1 - i].astype(jnp.float32)
    return out.astype(x.dtype)


def ssd_chunked(
    x: jax.Array,     # (B, S, H, P)
    dt: jax.Array,    # (B, S, H) — post-softplus
    A: jax.Array,     # (H,) negative
    Bm: jax.Array,    # (B, S, N)  (ngroups = 1, broadcast over heads)
    Cm: jax.Array,    # (B, S, N)
    D: jax.Array,     # (H,)
    chunk: int,
    init_state: Optional[jax.Array] = None,   # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    xc = x.reshape(Bsz, nc, Q, H, P).transpose(1, 0, 2, 3, 4).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, Q, H).transpose(1, 0, 2, 3).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, Q, N).transpose(1, 0, 2, 3).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, Q, N).transpose(1, 0, 2, 3).astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def per_chunk(state, inp):
        xq, dtq, bq, cq = inp                     # (B,Q,H,P),(B,Q,H),(B,Q,N),(B,Q,N)
        dA = dtq * Af[None, None, :]              # (B,Q,H)
        cs = jnp.cumsum(dA, axis=1)               # (B,Q,H) inclusive
        total = cs[:, -1, :]                      # (B,H)
        # intra-chunk: L[i,j] = exp(cs_i - cs_j) for i >= j   (B,H,Q,Q).
        # Mask BEFORE the exp: for i < j the exponent is positive and large,
        # and where(tri, exp(seg), 0) would leak inf into the backward pass.
        seg = cs[:, :, None, :] - cs[:, None, :, :]          # (B,Qi,Qj,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
        Lmat = jnp.exp(jnp.where(tri, seg, -60.0)) * tri.astype(jnp.float32)
        scores = jnp.einsum("bin,bjn->bij", cq, bq)          # (B,Qi,Qj)
        W = scores[:, :, :, None] * Lmat * dtq[:, None, :, :]  # (B,Qi,Qj,H)
        y_diag = jnp.einsum("bijh,bjhp->bihp", W, xq)
        # inter-chunk: contribution of carried state
        y_off = jnp.einsum("bin,bhpn->bihp", cq, state) * jnp.exp(cs)[..., None]
        # new chunk state
        decay_to_end = jnp.exp(total[:, None, :] - cs)       # (B,Q,H)
        Sc = jnp.einsum("bjn,bjh,bjhp->bhpn", bq, dtq * decay_to_end, xq)
        state_new = state * jnp.exp(total)[:, :, None, None] + Sc
        y = y_diag + y_off + xq * D[None, None, :, None]
        return state_new, y

    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((Bsz, H, P, N), jnp.float32))
    final_state, yc = lax.scan(per_chunk, s0, (xc, dtc, Bc, Cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(Bsz, Sp, H, P)[:, :S]
    return y.astype(x.dtype), final_state


def ssd_decode_step(
    state: jax.Array,  # (B, H, P, N)
    x: jax.Array,      # (B, H, P)
    dt: jax.Array,     # (B, H)
    A: jax.Array,      # (H,)
    Bm: jax.Array,     # (B, N)
    Cm: jax.Array,     # (B, N)
    D: jax.Array,      # (H,)
) -> Tuple[jax.Array, jax.Array]:
    """O(1) recurrent update; returns (y (B,H,P), new_state)."""
    xf = x.astype(jnp.float32)
    dA = jnp.exp(dt.astype(jnp.float32) * A[None, :])            # (B,H)
    dBx = jnp.einsum("bn,bhp->bhpn", Bm.astype(jnp.float32),
                     dt.astype(jnp.float32)[..., None] * xf)
    state_new = state * dA[:, :, None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", state_new, Cm.astype(jnp.float32))
    y = y + xf * D[None, :, None]
    return y.astype(x.dtype), state_new


def mamba2_forward(
    params: Dict,
    x: jax.Array,          # (B, S, d)
    cfg,
    init_state: Optional[jax.Array] = None,
    conv_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Full Mamba-2 mixer over a sequence; returns (out, final_ssm_state)."""
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    zxbcdt = x @ params["in_proj"]                               # (B,S,2di+2N+H)
    z, xs, B_, C_, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xs, B_, C_], axis=-1)             # (B,S,di+2N)
    conv = _depthwise_causal_conv(conv_in, params["conv_w"]) + params["conv_b"]
    conv = jax.nn.silu(conv)
    xs, B_, C_ = jnp.split(conv, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    Bsz, S = x.shape[0], x.shape[1]
    y, state = ssd_chunked(
        xs.reshape(Bsz, S, H, P), dt, A, B_, C_, params["d_skip"],
        cfg.ssm_chunk, init_state,
    )
    y = y.reshape(Bsz, S, di)
    y = y * jax.nn.silu(z)
    # grouped RMS norm (mamba2's norm before out-proj)
    from .layers import rms_norm
    y = rms_norm(y, params["norm"], cfg.rms_eps)
    return y @ params["out_proj"], state


def mamba2_decode(
    params: Dict,
    x: jax.Array,          # (B, d) single token
    cfg,
    ssm_state: jax.Array,  # (B, H, P, N)
    conv_state: jax.Array, # (B, K-1, di+2N) rolling window of past conv inputs
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token step; returns (out (B,d), ssm_state', conv_state')."""
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    K = cfg.ssm_conv
    zxbcdt = x @ params["in_proj"]                               # (B,2di+2N+H)
    z, xs, B_, C_, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xs, B_, C_], axis=-1)             # (B, di+2N)
    window = jnp.concatenate([conv_state, conv_in[:, None, :]], axis=1)  # (B,K,·)
    # Tap order must mirror _depthwise_causal_conv: w[0] multiplies the
    # CURRENT sample, w[K-1] the oldest — window is oldest-first, so flip.
    conv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                      params["conv_w"][::-1].astype(jnp.float32)) + params["conv_b"]
    conv = jax.nn.silu(conv).astype(x.dtype)
    xs, B_, C_ = jnp.split(conv, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    y, ssm_state = ssd_decode_step(
        ssm_state, xs.reshape(-1, H, P), dt, A, B_, C_, params["d_skip"])
    y = y.reshape(-1, di) * jax.nn.silu(z)
    from .layers import rms_norm
    y = rms_norm(y, params["norm"], cfg.rms_eps)
    return y @ params["out_proj"], ssm_state, window[:, 1:, :]
