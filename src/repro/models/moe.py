"""Mixture-of-Experts layer with capacity-based top-k routing and optional
expert parallelism via ``all_to_all`` over the mesh's model axis.

Dispatch is top-C-per-expert (lax.top_k over the (E, T) routing matrix),
which bounds per-expert work exactly like GShard capacity but without the
(T, E, C) one-hot einsum — the dispatch tensors here are (E, C, d) gathers,
small enough to live per-shard at 32k tokens.  With expert parallelism the
buckets round-trip through two all_to_alls over the model axis (the standard
EP schedule); without a mesh the same code runs locally (M = 1).

Dropped tokens (beyond capacity) fall through with the residual connection,
as in GShard/Switch.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def router_probs(x: jax.Array, w_router: jax.Array) -> jax.Array:
    """(B,S,d) x (d,E) -> (T,E) float32 softmax probabilities."""
    t = x.reshape(-1, x.shape[-1])
    logits = t.astype(jnp.float32) @ w_router.astype(jnp.float32)
    return jax.nn.softmax(logits, axis=-1)


def load_balance_loss(probs: jax.Array, topk_idx: jax.Array, num_experts: int) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    T = probs.shape[0]
    counts = jnp.zeros((num_experts,), jnp.float32)
    counts = counts.at[topk_idx.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(topk_idx.size, 1)
    p = probs.mean(axis=0)
    return num_experts * jnp.sum(f * p)


def moe_ffn(
    params: Dict,
    x: jax.Array,
    cfg,
    *,
    axis: Optional[str] = None,
    axis_size: int = 1,
) -> jax.Array:
    """Top-k routed expert FFN.  ``x``: (B, S, d) (local shard if under
    shard_map).  ``params['experts']`` leaves have leading dim = local expert
    count (E / axis_size when sharded)."""
    B, S, d = x.shape
    E = cfg.num_experts
    k = cfg.experts_per_token
    tokens = x.reshape(-1, d)
    T = tokens.shape[0]

    logits = tokens.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    topk_p, topk_idx = lax.top_k(probs, k)                   # (T, k)
    if cfg.norm_topk:
        topk_p = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)

    # (E, T) routing matrix: weight if token routed to e else -1.
    routed = jnp.full((T, E), -1.0, jnp.float32)
    routed = routed.at[jnp.arange(T)[:, None], topk_idx].set(topk_p)
    routing = routed.T                                        # (E, T)

    C = int(cfg.capacity_factor * T * k / E) + 1
    C = min(max(4, C), T)
    gate_w, tok_idx = lax.top_k(routing, C)                  # (E, C)
    valid = gate_w > 0.0
    gate_w = jnp.where(valid, gate_w, 0.0)

    xe = tokens[tok_idx] * valid[..., None].astype(tokens.dtype)  # (E, C, d)

    if axis is not None and axis_size > 1:
        M = axis_size
        ep = E // M
        # (E, C, d) -> (M, ep, C, d) -> exchange shard<->expert-group.
        xe = xe.reshape(M, ep, C, d)
        xe = lax.all_to_all(xe, axis, split_axis=0, concat_axis=0, tiled=False)
        # now (M, ep, C, d) where dim0 = source shard; merge into capacity.
        xe = xe.transpose(1, 0, 2, 3).reshape(ep, M * C, d)
    else:
        ep = E

    # expert swiglu over stacked local experts
    wg, wu, wd = params["experts"]["w_gate"], params["experts"]["w_up"], params["experts"]["w_down"]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * jnp.einsum("ecd,edf->ecf", xe, wu)
    ye = jnp.einsum("ecf,efd->ecd", h, wd)                   # (ep, C', d)

    if axis is not None and axis_size > 1:
        M = axis_size
        ye = ye.reshape(ep, M, C, d).transpose(1, 0, 2, 3)    # (M, ep, C, d)
        ye = lax.all_to_all(ye, axis, split_axis=0, concat_axis=0, tiled=False)
        ye = ye.reshape(E, C, d)

    out = jnp.zeros((T, d), ye.dtype)
    out = out.at[tok_idx.reshape(-1)].add(
        (ye * gate_w[..., None].astype(ye.dtype)).reshape(-1, d)
    )

    if cfg.num_shared_experts:
        ws = params["shared"]
        hs = jax.nn.silu(tokens @ ws["w_gate"]) * (tokens @ ws["w_up"])
        out = out + hs @ ws["w_down"]

    return out.reshape(B, S, d).astype(x.dtype)
