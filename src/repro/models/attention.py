"""Attention: chunked-flash (online softmax) for train/prefill, plain masked
attention for single-token decode, GQA throughout, and MLA (DeepSeek-V2)
with weight-absorbed decode against the compressed KV cache.

The flash path is pure JAX (lax.scan over KV chunks) so that (a) prefill_32k
and train_4k lower with O(S·chunk) live attention memory instead of O(S²)
(compile-feasible & memory_analysis-honest at 32k), and (b) HLO FLOPs stay at
the 2·S²·D the roofline expects.  On TPU the same structure maps to the MXU
with (chunk x chunk) tiles; a Pallas flash kernel is deliberately NOT used —
the paper's kernels are stencils, and XLA already fuses this scan well.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    """(B,S,Hkv,D) -> (B,S,Hq,D): q head h reads kv head h // groups.

    Materialising the repeat keeps every attention einsum LOCAL under
    head-sharding (Hq divides the model axis even when Hkv doesn't); the
    copy is a few MB of bf16 versus the all-gathers a grouped layout forces.
    """
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    chunk: int = 1024,
    scale: Optional[float] = None,
) -> jax.Array:
    """Online-softmax attention, scanning KV chunks.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, Dk/Dv); returns (B, Sq, Hq, Dv).
    ``q_offset``: absolute position of q[0] (prefill-with-cache / decode).
    """
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    k = repeat_kv(k, G)
    v = repeat_kv(v, G)
    Dv = v.shape[-1]
    s = scale if scale is not None else D ** -0.5
    chunk = min(chunk, Skv)
    # pad KV to a multiple of chunk
    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, Hq, -1).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, Hq, -1).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(Sq)

    @jax.checkpoint  # recompute chunk scores in backward: O(S·C) live, not O(S²)
    def body(carry, inputs):
        m, l, o, c_idx = carry
        k_i, v_i = inputs
        scores = jnp.einsum("bshd,bchd->bhsc", q.astype(jnp.float32),
                            k_i.astype(jnp.float32)) * s      # (B,Hq,Sq,C)
        kv_pos = c_idx * chunk + jnp.arange(chunk)
        valid = kv_pos < Skv
        mask = valid[None, None, None, :]
        if causal:
            mask = mask & (kv_pos[None, None, None, :]
                           <= q_pos[None, :, None])
        scores = jnp.where(mask, scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        # NOTE (§Perf, refuted): casting p to bf16 for this matmul (the
        # hand-written-flash-kernel choice) measured WORSE on the compiled
        # module (+7% memory term: the converts add fusion-boundary traffic
        # in this lowering) and costs 1e-2 accuracy — kept in f32.
        pv = jnp.einsum("bhsc,bchd->bhsd", p, v_i.astype(jnp.float32))
        o_new = o * alpha[..., None] + pv
        return (m_new, l_new, o_new, c_idx + 1), None

    m0 = jnp.full((B, Hq, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hq, Sq), jnp.float32)
    o0 = jnp.zeros((B, Hq, Sq, Dv), jnp.float32)
    (m, l, o, _), _ = lax.scan(body, (m0, l0, o0, jnp.int32(0)), (kc, vc))
    o = o / jnp.maximum(l[..., None], 1e-30)
    out = o.transpose(0, 2, 1, 3)                              # (B,Sq,Hq,Dv)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_len: jax.Array,
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token attention against a cache.

    q: (B, 1, Hq, D); k/v_cache: (B, L, Hkv, D); cur_len: () or (B,) valid length
    (the new token's K/V must already be written at cur_len-1).
    """
    B, L = k_cache.shape[0], k_cache.shape[1]
    Hq, D = q.shape[2], q.shape[-1]
    G = Hq // k_cache.shape[2]
    s = scale if scale is not None else D ** -0.5
    k_r = repeat_kv(k_cache, G)
    v_r = repeat_kv(v_cache, G)
    scores = jnp.einsum("bshd,bchd->bhsc", q.astype(jnp.float32),
                        k_r.astype(jnp.float32)) * s           # (B,Hq,1,L)
    pos = jnp.arange(L)
    if cur_len.ndim == 0:
        mask = pos[None, :] < cur_len
    else:
        mask = pos[None, :] < cur_len[:, None]
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhsc,bchd->bhsd", p, v_r.astype(jnp.float32))
    return o.transpose(0, 2, 1, 3).astype(q.dtype)             # (B,1,Hq,Dv)


# -- MLA (DeepSeek-V2) ----------------------------------------------------------
def mla_expand(params: Dict, c_kv: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """Expand compressed cache to per-head K_nope/V (train & prefill path)."""
    k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhd->bshd", c_kv, params["w_uv"])
    return k_nope, v


def mla_decode_attention(
    params: Dict,
    q_nope: jax.Array,     # (B,1,H,dn)
    q_rope: jax.Array,     # (B,1,H,dr) — rope already applied
    ckv_cache: jax.Array,  # (B,L,r)
    krope_cache: jax.Array,  # (B,L,dr) — rope already applied
    cur_len: jax.Array,
    cfg,
) -> jax.Array:
    """Weight-absorbed MLA decode: attends in the compressed (rank-r) space —
    the whole point of MLA: the per-token cache is r + dr floats, not H·(dn+dv).
    Returns per-head context (B,1,H,dv)."""
    # absorb W_uk into q: q_eff (B,1,H,r)
    q_eff = jnp.einsum("bshd,rhd->bshr", q_nope.astype(jnp.float32),
                       params["w_uk"].astype(jnp.float32))
    s = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    scores = (jnp.einsum("bshr,blr->bhsl", q_eff, ckv_cache.astype(jnp.float32))
              + jnp.einsum("bshd,bld->bhsl", q_rope.astype(jnp.float32),
                           krope_cache.astype(jnp.float32))) * s
    L = ckv_cache.shape[1]
    pos = jnp.arange(L)
    mask = pos[None, :] < (cur_len if cur_len.ndim else cur_len[None])
    if cur_len.ndim == 0:
        mask = pos[None, :] < cur_len
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    ctx_r = jnp.einsum("bhsl,blr->bshr", p, ckv_cache.astype(jnp.float32))  # (B,1,H,r)
    # absorb W_uv on the way out: (B,1,H,dv)
    ctx = jnp.einsum("bshr,rhd->bshd", ctx_r, params["w_uv"].astype(jnp.float32))
    return ctx.astype(q_nope.dtype)
