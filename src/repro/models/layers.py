"""Shared building blocks: norms, rotary embeddings, MLPs, initialisers."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    emb = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(emb, dtype=jnp.float32)


# -- initialisers --------------------------------------------------------------
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


class KeyGen:
    """Deterministic key splitter that reads like a parameter registry."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub
